"""End-to-end driver: train a ~100M-param granite-family model for a few
hundred steps on CPU with the full production stack — keyed data pipeline
(balancer-partitioned sources), AdamW, checkpoint/restart, straggler
watchdog. Run:
  PYTHONPATH=src python examples/train_100m.py --steps 200
"""

import argparse
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.data.pipeline import KeyedDataPipeline, zipf_sources
from repro.train.optimizer import OptConfig
from repro.train.trainer import Trainer, TrainerConfig


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--ckpt", default="/tmp/repro_train_100m")
    args = ap.parse_args()

    # ~100M params: granite-8b family, narrowed
    cfg = dataclasses.replace(
        get_config("granite_8b"), n_layers=12, d_model=512, n_heads=8,
        n_kv_heads=2, head_dim=64, d_ff=2048, vocab=32_000)

    pipe = KeyedDataPipeline(zipf_sources(64, z=1.0), n_workers=1,
                             seq_len=args.seq, vocab=cfg.vocab)

    def data_fn(step):
        while True:
            if step % 20 == 0:
                pipe.drift()
            pipe.run_interval(n_docs=64)
            b = pipe.worker_batch(0, args.batch)
            if b is not None:
                return {k: jnp.asarray(v) for k, v in b.items()}

    tcfg = TrainerConfig(total_steps=args.steps, checkpoint_every=50,
                         microbatches=2, skewshield=False)
    tr = Trainer(cfg, OptConfig(lr=3e-4, warmup_steps=20,
                                total_steps=args.steps),
                 tcfg, args.ckpt, data_fn)
    if tr.try_resume():
        print(f"resumed from step {tr.step}")
    hist = tr.run()
    losses = [h["loss"] for h in hist]
    print(f"step {tr.step}: loss {losses[0]:.3f} -> {losses[-1]:.3f} "
          f"({np.mean([h['time_s'] for h in hist]):.2f}s/step)")
    tr.save()


if __name__ == "__main__":
    main()
